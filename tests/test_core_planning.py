"""Orchestrator + Dispatcher invariants (seeded property sweeps; no
optional-dependency requirement)."""
import random

import pytest

import repro.configs as C
from repro.core.dispatcher import Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.placement import (PLACEMENT_TYPES, PRIMARY_PLACEMENTS,
                                  PlacementPlan, primary_of_vr)
from repro.core.profiler import Profiler
from repro.core.request import Request

PIPES = list(C.PIPELINE_IDS)


@pytest.fixture(scope="module")
def profilers():
    return {p: Profiler(C.get(p)) for p in PIPES}


def _random_reqs(pid, prof, rng, n=40):
    from repro.core.workloads import MIXES
    classes = [cls for mix in MIXES[pid].values() for cls, _ in mix]
    out = []
    for i in range(n):
        res, sec = rng.choice(classes)
        r = Request(pid, res, float(sec), arrival=rng.uniform(0, 100))
        r.deadline = r.arrival + 2.5 * prof.pipeline_time(r)
        out.append(r)
    return out


@pytest.mark.parametrize("pid", PIPES)
def test_placement_covers_all_stages(profilers, pid):
    prof = profilers[pid]
    orch = Orchestrator(prof, num_chips=128)
    reqs = _random_reqs(pid, prof, random.Random(0))
    plan = orch.generate(reqs)
    assert plan.num_units == 128 // prof.k_min
    for s in "EDC":
        assert plan.units_with(s), f"{pid}: no unit hosts stage {s}"
    assert all(p in PLACEMENT_TYPES for p in plan.placements)


@pytest.mark.parametrize("pid", PIPES)
def test_optvr_monotone_feasibility(profilers, pid):
    """OptVR picks the min-communication feasible type; every type above it
    in the order must also be feasible (V3 = ⟨D⟩ has the least memory)."""
    prof = profilers[pid]
    orch = Orchestrator(prof, num_chips=128)
    reqs = _random_reqs(pid, prof, random.Random(1))
    for r in reqs:
        vr = orch.opt_vr(r)
        k = prof.optimal_degree(r, "D")
        assert prof.fits(r, primary_of_vr(vr), k) or vr == 3
        for earlier in range(vr):
            assert not prof.fits(r, primary_of_vr(earlier), k)


def test_split_conserves_units():
    rng = random.Random(0)
    for case in range(60):
        n_units = rng.randint(8, 64)
        rates = {"prim": rng.uniform(0.01, 10), "auxE": rng.uniform(0.01, 10),
                 "auxC": rng.uniform(0.01, 10)}
        for vr in range(4):
            counts = Orchestrator.split(n_units, vr, rates)
            assert sum(counts.values()) == n_units, (case, vr, counts)
            assert all(c >= 0 for c in counts.values())
            assert primary_of_vr(vr) in counts


@pytest.mark.parametrize("pid", PIPES)
def test_dispatcher_respects_budgets_and_nodes(profilers, pid):
    prof = profilers[pid]
    orch = Orchestrator(prof, num_chips=128)
    rng = random.Random(2)
    reqs = _random_reqs(pid, prof, rng, n=60)
    plan = orch.generate(reqs)
    disp = Dispatcher(prof)
    idle = set(range(plan.num_units))
    free_at = {g: 0.0 for g in idle}
    decisions = disp.dispatch(reqs, plan, idle, free_at, tau=0.0)
    assert decisions, pid
    used = set()
    for dec in decisions:
        # D units: correct type, intra-node, disjoint, idle
        ptypes = {plan.placements[g] for g in dec.d_units}
        assert len(ptypes) == 1 and "D" in ptypes.pop()
        nodes = {plan.node_of(g) for g in dec.d_units}
        assert len(nodes) == 1, "SP instance must be intra-node"
        assert not (set(dec.d_units) & used)
        used |= set(dec.d_units)
        assert len(dec.d_units) == dec.degree
        # E/C cover their stages
        assert all("E" in plan.placements[g] for g in dec.e_units)
        assert all("C" in plan.placements[g] for g in dec.c_units)
        # memory feasibility (the F filter)
        prim = plan.placements[dec.d_units[0]]
        assert prof.fits(dec.request, prim, dec.degree)


def test_dispatcher_prefers_low_comm_vr(profilers):
    """With every type idle and feasible, V0 (no inter-stage comm) wins."""
    prof = profilers["sd3"]
    plan = PlacementPlan(["EDC"] * 8 + ["DC"] * 8 + ["ED"] * 8 + ["D"] * 4
                         + ["E"] * 2 + ["C"] * 2, unit_size=prof.k_min)
    disp = Dispatcher(prof)
    r = Request("sd3", 512)
    r.deadline = 1e9
    idle = set(range(plan.num_units))
    decisions = disp.dispatch([r], plan, idle, {g: 0.0 for g in idle}, 0.0)
    assert decisions[0].vr_type == 0


def test_aging_eventually_dispatches_late_request(profilers):
    """W_r grows past the starvation threshold (App. C.2 aging)."""
    prof = profilers["sd3"]
    disp = Dispatcher(prof)
    late = Request("sd3", 1536)
    late.deadline = 0.1  # hopeless deadline
    options, budgets = disp.build_options(
        [late], tau=1000.0, idle_by_type={"EDC": 8, "DC": 0, "ED": 0, "D": 0})
    assert options[0], "late request must still get (aged) options"
    assert all(o.reward > 0 for o in options[0])


def test_cross_node_sp_selects_across_nodes(profilers):
    """Beyond-paper: pod-wide SP combines adjacent nodes when one node
    cannot host the degree (EXPERIMENTS.md §Perf pair 4)."""
    plan = PlacementPlan(["EDC"] * 32, unit_size=1, units_per_node=8)
    idle = set(range(32))
    assert Dispatcher.select_units(plan, "EDC", 16, idle) is None
    units = Dispatcher.select_units(plan, "EDC", 16, idle, cross_node=True)
    assert units is not None and len(units) == 16


def test_cross_node_profiler_extends_degrees(profilers):
    import repro.configs as C
    from repro.core.profiler import Profiler
    base = profilers["flux"]
    wide = Profiler(C.get("flux"), cross_node_sp=True)
    assert wide.max_degree_units > base.max_degree_units
    heavy = Request("flux", 4096)
    assert wide.optimal_degree(heavy, "D") >= base.optimal_degree(heavy, "D")


# -- Split() invariants across a randomized rate grid -------------------------

def test_split_invariants_randomized_rate_grid():
    """Counts sum to n_t, nothing negative, the primary keeps at least one
    unit whenever n_t >= 1, and V3's aux-capacity feasibility loop settles
    (n_p == 1 or both aux pools cover the primary's service rate)."""
    rng = random.Random(1234)
    for case in range(400):
        n_t = rng.randint(1, 64)
        # extreme rate ratios included on purpose: the degenerate n_t <= 2
        # cases used to let the aux buckets swallow the whole budget
        rates = {"prim": 10 ** rng.uniform(-3, 3),
                 "auxE": 10 ** rng.uniform(-3, 3),
                 "auxC": 10 ** rng.uniform(-3, 3)}
        for vr in range(4):
            counts = Orchestrator.split(n_t, vr, rates)
            prim = primary_of_vr(vr)
            assert sum(counts.values()) == n_t, (case, vr, counts)
            assert all(c >= 0 for c in counts.values()), (case, vr, counts)
            assert counts.get(prim, 0) >= 1, (case, vr, counts)
            if vr in (1, 2) and n_t >= 2:
                # the aux placement must exist once there is room for it
                aux = sum(c for t, c in counts.items() if t != prim)
                assert aux >= 1, (case, vr, counts)
            if vr == 3:
                n_p = counts[prim]
                n_e = counts.get("E", 0)
                n_c = counts.get("C", 0)
                v_p, v_e, v_c = rates["prim"], rates["auxE"], rates["auxC"]
                assert (n_p == 1
                        or (n_e * v_e >= n_p * v_p and n_c * v_c >= n_p * v_p)
                        ), (case, counts, rates)


# -- PackPerMachine drift correction ------------------------------------------

def test_pack_drift_never_zeroes_the_only_primary(profilers):
    """Regression: a large negative drift used to be lump-subtracted from
    the largest bucket — silently zeroing it even when it was the only
    D-carrying one, leaving a plan that can never run Diffuse."""
    prof = profilers["sd3"]
    orch = Orchestrator(prof, num_chips=4 * prof.k_min)
    plan = orch.pack_per_machine({"EDC": 40, "E": 2, "C": 2})
    assert plan.num_units == orch.num_units
    assert any(p in PRIMARY_PLACEMENTS for p in plan.placements), \
        plan.type_histogram()


def test_pack_drift_redistributes_across_buckets(profilers):
    """Negative drift sheds from the largest buckets one unit at a time
    instead of lump-zeroing one of them, so every over-provisioned bucket
    shrinks proportionally and none silently disappears."""
    prof = profilers["sd3"]
    orch = Orchestrator(prof, num_chips=16 * prof.k_min)
    plan = orch.pack_per_machine({"D": 4, "E": 30, "C": 30})
    hist = plan.type_histogram()
    assert plan.num_units == 16
    assert hist.get("D", 0) >= 1
    # both aux stages must survive the shed (the old lump subtraction could
    # zero one of them entirely)
    assert hist.get("E", 0) >= 1 and hist.get("C", 0) >= 1, hist


def test_pack_positive_drift_still_fills(profilers):
    prof = profilers["sd3"]
    orch = Orchestrator(prof, num_chips=32 * prof.k_min)
    plan = orch.pack_per_machine({"EDC": 3, "E": 1})
    assert plan.num_units == 32
    assert plan.count_of_type("EDC") >= 3


# -- multiplicity-aware dispatch aggregation ----------------------------------

def test_dispatcher_aggregate_matches_plain_on_flood(profilers):
    """A dense same-class flood must dispatch the same work with and
    without aggregation — while the aggregated solver sees a
    capacity-bounded instance instead of one row per request."""
    from repro.core.request import Request as Req
    prof = profilers["sd3"]
    orch = Orchestrator(prof, num_chips=128)
    flood = []
    for _ in range(300):
        r = Req("sd3", 512, arrival=0.0)
        r.deadline = 1e9
        flood.append(r)
    plan = orch.generate(flood)
    idle = set(range(plan.num_units))
    free_at = {g: 0.0 for g in idle}
    import collections
    outcomes = {}
    for agg in (False, True):
        disp = Dispatcher(prof, aggregate=agg)
        decs = disp.dispatch(list(flood), plan, set(idle), dict(free_at), 0.0)
        outcomes[agg] = (
            collections.Counter((d.vr_type, d.degree) for d in decs),
            disp.last_solve_stats)
    hist_plain, stats_plain = outcomes[False]
    hist_agg, stats_agg = outcomes[True]
    assert hist_agg == hist_plain
    assert abs(stats_agg["reward"] - stats_plain["reward"]) < 1e-6
    # the flood collapses to one group, capacity-capped
    assert stats_agg["n_groups"] == 1
    assert stats_agg["n_solved"] < stats_plain["n_solved"]
