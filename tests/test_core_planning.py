"""Orchestrator + Dispatcher invariants (seeded property sweeps; no
optional-dependency requirement)."""
import random

import pytest

import repro.configs as C
from repro.core.dispatcher import Dispatcher
from repro.core.orchestrator import Orchestrator
from repro.core.placement import (PLACEMENT_TYPES, PRIMARY_PLACEMENTS,
                                  PlacementPlan, VIRTUAL_REPLICAS,
                                  primary_of_vr)
from repro.core.profiler import Profiler
from repro.core.request import Request

PIPES = list(C.PIPELINE_IDS)


@pytest.fixture(scope="module")
def profilers():
    return {p: Profiler(C.get(p)) for p in PIPES}


def _random_reqs(pid, prof, rng, n=40):
    from repro.core.workloads import MIXES
    classes = [cls for mix in MIXES[pid].values() for cls, _ in mix]
    out = []
    for i in range(n):
        res, sec = rng.choice(classes)
        r = Request(pid, res, float(sec), arrival=rng.uniform(0, 100))
        r.deadline = r.arrival + 2.5 * prof.pipeline_time(r)
        out.append(r)
    return out


@pytest.mark.parametrize("pid", PIPES)
def test_placement_covers_all_stages(profilers, pid):
    prof = profilers[pid]
    orch = Orchestrator(prof, num_chips=128)
    reqs = _random_reqs(pid, prof, random.Random(0))
    plan = orch.generate(reqs)
    assert plan.num_units == 128 // prof.k_min
    for s in "EDC":
        assert plan.units_with(s), f"{pid}: no unit hosts stage {s}"
    assert all(p in PLACEMENT_TYPES for p in plan.placements)


@pytest.mark.parametrize("pid", PIPES)
def test_optvr_monotone_feasibility(profilers, pid):
    """OptVR picks the min-communication feasible type; every type above it
    in the order must also be feasible (V3 = ⟨D⟩ has the least memory)."""
    prof = profilers[pid]
    orch = Orchestrator(prof, num_chips=128)
    reqs = _random_reqs(pid, prof, random.Random(1))
    for r in reqs:
        vr = orch.opt_vr(r)
        k = prof.optimal_degree(r, "D")
        assert prof.fits(r, primary_of_vr(vr), k) or vr == 3
        for earlier in range(vr):
            assert not prof.fits(r, primary_of_vr(earlier), k)


def test_split_conserves_units():
    rng = random.Random(0)
    for case in range(60):
        n_units = rng.randint(8, 64)
        rates = {"prim": rng.uniform(0.01, 10), "auxE": rng.uniform(0.01, 10),
                 "auxC": rng.uniform(0.01, 10)}
        for vr in range(4):
            counts = Orchestrator.split(n_units, vr, rates)
            assert sum(counts.values()) == n_units, (case, vr, counts)
            assert all(c >= 0 for c in counts.values())
            assert primary_of_vr(vr) in counts


@pytest.mark.parametrize("pid", PIPES)
def test_dispatcher_respects_budgets_and_nodes(profilers, pid):
    prof = profilers[pid]
    orch = Orchestrator(prof, num_chips=128)
    rng = random.Random(2)
    reqs = _random_reqs(pid, prof, rng, n=60)
    plan = orch.generate(reqs)
    disp = Dispatcher(prof)
    idle = set(range(plan.num_units))
    free_at = {g: 0.0 for g in idle}
    decisions = disp.dispatch(reqs, plan, idle, free_at, tau=0.0)
    assert decisions, pid
    used = set()
    for dec in decisions:
        # D units: correct type, intra-node, disjoint, idle
        ptypes = {plan.placements[g] for g in dec.d_units}
        assert len(ptypes) == 1 and "D" in ptypes.pop()
        nodes = {plan.node_of(g) for g in dec.d_units}
        assert len(nodes) == 1, "SP instance must be intra-node"
        assert not (set(dec.d_units) & used)
        used |= set(dec.d_units)
        assert len(dec.d_units) == dec.degree
        # E/C cover their stages
        assert all("E" in plan.placements[g] for g in dec.e_units)
        assert all("C" in plan.placements[g] for g in dec.c_units)
        # memory feasibility (the F filter)
        prim = plan.placements[dec.d_units[0]]
        assert prof.fits(dec.request, prim, dec.degree)


def test_dispatcher_prefers_low_comm_vr(profilers):
    """With every type idle and feasible, V0 (no inter-stage comm) wins."""
    prof = profilers["sd3"]
    plan = PlacementPlan(["EDC"] * 8 + ["DC"] * 8 + ["ED"] * 8 + ["D"] * 4
                         + ["E"] * 2 + ["C"] * 2, unit_size=prof.k_min)
    disp = Dispatcher(prof)
    r = Request("sd3", 512)
    r.deadline = 1e9
    idle = set(range(plan.num_units))
    decisions = disp.dispatch([r], plan, idle, {g: 0.0 for g in idle}, 0.0)
    assert decisions[0].vr_type == 0


def test_aging_eventually_dispatches_late_request(profilers):
    """W_r grows past the starvation threshold (App. C.2 aging)."""
    prof = profilers["sd3"]
    disp = Dispatcher(prof)
    late = Request("sd3", 1536)
    late.deadline = 0.1  # hopeless deadline
    options, budgets = disp.build_options(
        [late], tau=1000.0, idle_by_type={"EDC": 8, "DC": 0, "ED": 0, "D": 0})
    assert options[0], "late request must still get (aged) options"
    assert all(o.reward > 0 for o in options[0])


def test_cross_node_sp_selects_across_nodes(profilers):
    """Beyond-paper: pod-wide SP combines adjacent nodes when one node
    cannot host the degree (EXPERIMENTS.md §Perf pair 4)."""
    prof = profilers["sd3"]
    plan = PlacementPlan(["EDC"] * 32, unit_size=1, units_per_node=8)
    idle = set(range(32))
    assert Dispatcher.select_units(plan, "EDC", 16, idle) is None
    units = Dispatcher.select_units(plan, "EDC", 16, idle, cross_node=True)
    assert units is not None and len(units) == 16


def test_cross_node_profiler_extends_degrees(profilers):
    import repro.configs as C
    from repro.core.profiler import Profiler
    base = profilers["flux"]
    wide = Profiler(C.get("flux"), cross_node_sp=True)
    assert wide.max_degree_units > base.max_degree_units
    heavy = Request("flux", 4096)
    assert wide.optimal_degree(heavy, "D") >= base.optimal_degree(heavy, "D")
