"""Cross-pipeline unit lending (core/lending.py).

Covers: the fleet plan's lending map, FleetMonitor pressure windows, broker
grant/return mechanics (min-hold, reload charging, lender budgets), the
diffuse-path invariant (borrowed units host E/C only), off-path purity
(lending disabled leaves zero lending side effects), and the headline
behavior — sub-window decode bursts on one pipeline ride on a neighbour's
idle units and the backlogged pipeline's tail improves.
"""
import pytest

import repro.configs as C
from repro.core import workloads
from repro.core.fleet import (FleetConfig, FleetOrchestrator, FleetSimulator,
                              FLEET_SCHEDULERS, PipelineRegistry, run_fleet)
from repro.core.monitor import FleetMonitor
from repro.core.profiler import Profiler

# calm sizing window (the first fleet demand window), then anti-correlated
# sub-window decode bursts: cogvideox spikes while sd3 is in its lull —
# exactly the stranded-capacity regime unit lending recovers.  One tuned
# definition, shared with ``benchmarks/e2e.py --lending``.
BURSTY = workloads.BURSTY_EC
RATES = workloads.LENDING_RATES


def _run(lending, duration=600.0, seed=0, **cfg_kw):
    cfg = FleetConfig(num_chips=256, lending=lending, **cfg_kw)
    registry = PipelineRegistry(("sd3", "cogvideox"))
    profs = {p: registry.profiler(p) for p in registry.pipelines}
    trace = workloads.fleet_trace(("sd3", "cogvideox"), duration, profs,
                                  seed=seed, rates=RATES, phases=BURSTY,
                                  level="medium")
    orch = FleetOrchestrator(registry, num_chips=256, chips_per_node=8)
    sim = FleetSimulator(registry, FLEET_SCHEDULERS["adaptive"](orch, cfg),
                         trace, cfg)
    return sim, sim.run()


# -- lending map ---------------------------------------------------------------

def test_fleet_plan_lending_map():
    registry = PipelineRegistry(("sd3", "flux"))
    orch = FleetOrchestrator(registry, num_chips=128, chips_per_node=8)
    plan = orch.generate({}, orch.budgets({"sd3": 1.0, "flux": 1.0}))
    lmap = plan.lending_map(registry)
    assert lmap, "a 2-pipeline plan must expose lendable units"
    seen = set()
    for node, units in lmap.items():
        for lu in units:
            seen.add(lu.pipeline)
            assert lu.node == node
            lo, hi = plan.chip_ranges[lu.pipeline]
            assert lo <= node * plan.chips_per_node < hi
            for (borrower, stage), cost in lu.borrow_cost.items():
                assert borrower != lu.pipeline
                assert stage in ("E", "C")
                assert cost > 0.0
            assert lu.return_cost > 0.0
    # sd3 units are lendable to flux and vice versa only where unit sizes
    # allow: flux units (k_min=2) can host sd3 work (k_min=1), but sd3's
    # 1-chip units cannot hold a flux scheduling unit
    sd3_units = [lu for us in lmap.values() for lu in us if lu.pipeline == "sd3"]
    flux_units = [lu for us in lmap.values() for lu in us if lu.pipeline == "flux"]
    assert all(("flux", "C") not in lu.borrow_cost for lu in sd3_units)
    assert all(("sd3", "C") in lu.borrow_cost for lu in flux_units)
    assert seen == {"flux"} or seen == {"sd3", "flux"}


# -- monitor pressure windows --------------------------------------------------

def test_fleet_monitor_lending_windows():
    mon = FleetMonitor(t_win=100.0, lend_win=10.0)
    for i in range(5):
        mon.record_util(float(i), "a", 4.0, 2)
        mon.record_util(float(i), "b", 0.0, 10)
    assert abs(mon.backlog_pressure(4.0)["a"] - 4.0) < 1e-9
    assert abs(mon.idle_supply(4.0)["b"] - 10.0) < 1e-9
    # lend window slides independently of (and faster than) t_win
    assert mon.next_window_boundary() == 10.0
    mon.record_util(30.0, "a", 0.0, 8)
    assert mon.backlog_pressure(30.0)["a"] == 0.0
    assert mon.idle_supply(30.0)["a"] == 8.0


# -- broker mechanics ----------------------------------------------------------

@pytest.fixture(scope="module")
def lending_run():
    sim, res = _run(lending=True)
    return sim, res


@pytest.fixture(scope="module")
def plain_run():
    sim, res = _run(lending=False)
    return sim, res


def test_loans_flow_to_the_backlogged_pipeline(lending_run):
    sim, res = lending_run
    assert res.loans > 0, "bursty trace must trigger lending"
    assert res.borrowed_unit_seconds > 0.0
    # the decode-heavy bursty pipeline borrows; the image pipeline lends
    assert all(lender == "sd3" and borrower == "cogvideox"
               for lender, borrower in sim.broker.loans_by_pair)
    assert sum(sim.lanes["cogvideox"].borrowed_stage_runs.values()) > 0


def test_loans_charge_reloads_and_respect_min_hold(lending_run):
    sim, res = lending_run
    assert res.lend_swap_cost_s > 0.0, "weight reloads must be charged"
    # every borrow and every return is one reload; still-open loans have
    # only paid the borrow half
    assert sim.broker.reloads >= res.loans
    # min-hold: voluntary returns only happen after lend_min_hold seconds
    # (re-partitions may force-close loans early — those are counted
    # separately), so the borrowed time must cover at least min_hold per
    # voluntarily closed loan
    voluntary = (res.loans - len(sim.broker.active)
                 - sim.broker.forced_returns)
    assert voluntary >= 0
    if voluntary:
        assert res.borrowed_unit_seconds >= \
            0.9 * voluntary * sim.cfg.lend_min_hold


def test_diffuse_path_never_touches_borrowed_units(lending_run):
    sim, res = lending_run
    # borrowed slots host only E/C placements (the _record assert enforces
    # the per-dispatch invariant during the run; check the slots too)
    for lane in sim.lanes.values():
        for uid in range(lane.base_units, len(lane.engine.units)):
            assert lane.engine.units[uid].placement in ("E", "C")
    assert set(res.borrowed_stage_runs) <= {"E", "C"}


def test_lender_keeps_its_own_tail(lending_run, plain_run):
    """The utilization-budget gate: lending must not wreck the lender."""
    _, on = lending_run
    _, off = plain_run
    sd3_on = on.per_pipeline["sd3"]
    sd3_off = off.per_pipeline["sd3"]
    assert sd3_on["p95_s"] <= 1.5 * sd3_off["p95_s"]
    assert sd3_on["slo"] >= sd3_off["slo"] - 0.05


def test_lending_improves_the_backlogged_tail(lending_run, plain_run):
    """The tentpole claim at test scale: sub-window decode bursts ride on
    borrowed units and the worst pipeline's tail improves."""
    _, on = lending_run
    _, off = plain_run
    worst_on = max(m["p95_s"] for m in on.per_pipeline.values())
    worst_off = max(m["p95_s"] for m in off.per_pipeline.values())
    assert worst_on < worst_off
    assert on.slo_attainment >= off.slo_attainment


def test_lane_replace_keeps_loans_consistent(lending_run):
    """A lane-level placement switch during active loans must neither
    reactivate a lender's lent-out unit (double-booking its chips) nor
    count borrowed overlay slots in the layout histogram that
    ``maybe_replace`` compares against freshly generated plans."""
    sim, _ = lending_run
    for lane in sim.lanes.values():
        plan = lane.engine.plan
        hist_total = sum(plan.type_histogram().values())
        assert hist_total == lane.base_units, \
            "loan slots leaked into the layout histogram"
    for loan in sim.broker.active:
        lender_plan = sim.lanes[loan.lender].engine.plan
        assert not lender_plan.is_active(loan.lender_uid), \
            "lent-out unit active in the lender's plan (double-booked)"
        assert sim.lanes[loan.borrower].engine.plan.is_active(loan.slot)


# -- off-path purity -----------------------------------------------------------

def test_lending_off_leaves_no_side_effects(plain_run):
    sim, res = plain_run
    assert sim.broker is None
    assert res.loans == 0
    assert res.borrowed_unit_seconds == 0.0
    assert res.lend_swap_cost_s == 0.0
    assert res.borrowed_stage_runs == {}
    for lane in sim.lanes.values():
        assert len(lane.engine.units) == lane.base_units
        assert lane.borrowed_units == {}
        # the lending-pressure windows stay empty: no extra wake-up sources
        assert not sim.fleet_monitor._util


def test_lending_defaults_off():
    assert FleetConfig().lending is False
    assert FleetConfig().idle_window_wakeups is False


def test_single_pipeline_fleet_ignores_lending():
    """A 1-pipeline fleet has nobody to borrow from: lending on must be a
    no-op and reproduce the lending-off run exactly."""
    prof = Profiler(C.get("sd3"))
    t1 = workloads.make_trace("sd3", "medium", 45.0, prof, seed=3)
    t2 = workloads.make_trace("sd3", "medium", 45.0, prof, seed=3)
    base = run_fleet(["sd3"], mode="adaptive",
                     cfg=FleetConfig(num_chips=128), trace=t1)
    lent = run_fleet(["sd3"], mode="adaptive",
                     cfg=FleetConfig(num_chips=128, lending=True), trace=t2)
    assert lent.loans == 0
    assert lent.slo_attainment == base.slo_attainment
    assert lent.mean_latency == base.mean_latency
    assert lent.p95_latency == base.p95_latency
    assert lent.n_finished == base.n_finished
