"""Sharding rules + sequence parallelism (multi-device via subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.models import transformer as tf
from repro.sharding import partition

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.parametrize("arch", list(C.ARCH_IDS))
def test_param_specs_cover_tree(arch):
    cfg = C.get(arch)
    shapes = jax.eval_shape(lambda k: tf.init(cfg, k), jax.random.PRNGKey(0))
    specs = partition.param_specs(cfg, shapes)
    flat_shapes = jax.tree_util.tree_leaves(shapes)
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for sh, sp in zip(flat_shapes, flat_specs):
        assert len(sp) <= sh.ndim


@pytest.mark.parametrize("arch", ["gemma2-9b", "yi-34b", "deepseek-moe-16b",
                                  "rwkv6-3b"])
def test_divisibility_validation(arch):
    """After validation every sharded dim divides the mesh axis size."""
    cfg = C.get(arch)
    shapes = jax.eval_shape(lambda k: tf.init(cfg, k), jax.random.PRNGKey(0))
    specs = partition.param_specs(cfg, shapes)

    class FakeMesh:
        shape = {"model": 16, "data": 16}

    fixed = partition.validate_divisibility(specs, shapes, FakeMesh())
    flat_sh = jax.tree_util.tree_leaves(shapes)
    flat_sp = jax.tree_util.tree_leaves(fixed,
                                        is_leaf=lambda x: isinstance(x, P))
    for sh, sp in zip(flat_sh, flat_sp):
        for dim, ax in enumerate(tuple(sp)):
            if ax is not None:
                axes = (ax,) if isinstance(ax, str) else ax
                size = 1
                for a in axes:
                    size *= FakeMesh.shape[a]
                assert sh.shape[dim] % size == 0, (arch, sp, sh.shape)


def _run_subprocess(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=REPO_SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]


def test_ulysses_matches_reference_4dev():
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding import sequence_parallel as sp
        from repro.kernels import ops
        mesh = jax.make_mesh((4,), ("model",))
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 32, 8, 16))
        k = jax.random.normal(ks[1], (2, 32, 8, 16))
        v = jax.random.normal(ks[2], (2, 32, 8, 16))
        out = sp.ulysses_attention(q, k, v, mesh, causal=True)
        ref = ops.flash_attention(q, k, v, causal=True, use_kernel=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    """)


def test_scan_chunk_parallel_matches_reference_4dev():
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.sharding import sequence_parallel as sp
        from repro.kernels import ref
        mesh = jax.make_mesh((4,), ("model",))
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (2, 3, 64, 8))
        k = jax.random.normal(ks[1], (2, 3, 64, 8))
        v = jax.random.normal(ks[2], (2, 3, 64, 8))
        w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (2, 3, 64, 8)) * 0.3))
        o1, s1 = sp.scan_chunk_parallel(q, k, v, w, mesh)
        o2, s2 = ref.linear_scan_ref(q, k, v, w)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=3e-3, rtol=3e-3)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=3e-3, rtol=3e-3)
    """)


def test_sharded_train_step_runs_8dev():
    """A reduced model trains under pjit on a 4x2 mesh (data x model)."""
    _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        import repro.configs as C
        from repro.data import pipeline as dp
        from repro.sharding import partition
        from repro.training import loop
        from jax.sharding import NamedSharding, PartitionSpec as P
        cfg = C.get_smoke("deepseek-moe-16b")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        state = loop.init_state(cfg, jax.random.PRNGKey(0))
        sspec = partition.state_specs(cfg, jax.eval_shape(lambda: state))
        sspec = partition.validate_divisibility(
            sspec, jax.eval_shape(lambda: state), mesh)
        shard = partition.named(sspec, mesh)
        state = jax.device_put(state, shard)
        dcfg = dp.DataConfig(batch=4, seq_len=16)
        batch = {k: jax.device_put(jnp.asarray(v), NamedSharding(
                     mesh, P("data", *([None] * (v.ndim - 1)))))
                 for k, v in dp.synthetic_batch(cfg, dcfg, 0).items()}
        step = jax.jit(loop.make_train_step(cfg), in_shardings=(shard, None))
        with mesh:
            state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
    """)
