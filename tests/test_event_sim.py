"""Event-driven clock vs the legacy fixed-tick clock.

The event-driven simulator quantizes wake-ups onto the tick grid, so on
traces where the skipped ticks are no-ops it must reproduce the tick
simulator's results exactly — while doing far fewer scheduler wake-ups on
sparse traces.  Also covers the unified ``Orchestrator.generate`` ->
``maybe_replace`` infeasibility contract.
"""
import pytest

import repro.configs as C
from repro.core.baselines import BASELINES
from repro.core.orchestrator import Orchestrator
from repro.core.profiler import Profiler
from repro.core.request import Request
from repro.core.simulator import PendingSet, SimConfig, Simulator, run_sim
from repro.core.trident import TridentScheduler

SCENARIOS = [
    ("sd3", TridentScheduler, "light", 30.0),
    ("hunyuanvideo", TridentScheduler, "medium", 60.0),
    ("sd3", BASELINES["B1"], "light", 30.0),
    ("sd3", BASELINES["B4"], "light", 30.0),
    ("hunyuanvideo", BASELINES["B6"], "heavy", 90.0),
]


def _pair(pid, cls, wl, dur):
    tick = run_sim(pid, cls, wl, dur, sim_cfg=SimConfig(mode="tick"))
    event = run_sim(pid, cls, wl, dur, sim_cfg=SimConfig(mode="event"))
    return tick, event


@pytest.mark.parametrize("pid,cls,wl,dur", SCENARIOS,
                         ids=[f"{p}-{c.name}-{w}" for p, c, w, _ in SCENARIOS])
def test_event_clock_matches_tick_clock(pid, cls, wl, dur):
    tick, event = _pair(pid, cls, wl, dur)
    assert event.slo_attainment == tick.slo_attainment
    assert event.vr_histogram == tick.vr_histogram
    assert event.n_finished == tick.n_finished
    assert event.n_requests == tick.n_requests
    for a, b in ((tick.mean_latency, event.mean_latency),
                 (tick.p95_latency, event.p95_latency)):
        assert abs(a - b) <= 1e-6 * max(1.0, abs(a)), (a, b)
    assert event.placement_switches == tick.placement_switches


def test_event_clock_does_fewer_wakeups_on_sparse_trace():
    """The point of the tentpole: O(events), not O(horizon/tick)."""
    tick, event = _pair("hunyuanvideo", TridentScheduler, "medium", 60.0)
    assert event.sched_wakeups < tick.sched_wakeups / 2


def test_event_clock_handles_oom_and_empty_trace():
    r = run_sim("flux", BASELINES["B1"], "medium", 30.0)   # colocated OOM
    assert r.oom
    prof = Profiler(C.get("sd3"))
    sched = TridentScheduler(prof, SimConfig(), [])
    sim = Simulator("sd3", sched, [], SimConfig())
    res = sim.run()
    assert res.n_requests == 0 and not res.oom


def test_pending_set_is_id_indexed():
    a, b = Request("sd3", 512), Request("sd3", 1024)
    ps = PendingSet()
    ps.add(a)
    ps.append(b)          # list-style alias
    assert a in ps and b in ps and len(ps) == 2
    assert list(ps) == [a, b]   # admission order preserved
    ps.remove(a)
    assert a not in ps and len(ps) == 1
    ps.discard(a)         # idempotent
    with pytest.raises(KeyError):
        ps.remove(a)


def test_completion_events_use_the_unified_kernel_format():
    """Regression: every driver pushes the kernel's one completion format —
    (finish, seq, lane, stage, ptype, duration, batch members, units) —
    and the simulator's ``_events`` view is the kernel heap itself.  The
    trailing ``units`` field is ``()`` unless a fleet driver opted into
    unit tracking (``Lane.track_units``, core/elastic.py)."""
    r = Request("sd3", 512)
    prof = Profiler(C.get("sd3"))
    sched = TridentScheduler(prof, SimConfig(), [r])
    sim = Simulator("sd3", sched, [r], SimConfig())
    sim.engine = type("_E", (), {})()
    plan = Orchestrator(prof, num_chips=8).generate([r])
    sim.engine.plan = plan
    from repro.core.dispatcher import DispatchDecision
    dec = DispatchDecision(request=r, vr_type=0, degree=1,
                           d_units=(0,), e_units=(0,), c_units=(0,))
    sim.record_decision(dec, {"E": (0.0, 1.0), "D": (1.0, 2.0),
                              "C": (2.0, 3.0)})
    assert len(sim._events) == 3
    assert sim._events is sim.clock.completions
    for ev in sim._events:
        assert len(ev) == 8
        fin, seq, lane, stage, ptype, dur, members, units = ev
        assert lane == "sd3" and members == (r,) and dur >= 0.0
        assert units == ()   # zero-overhead default: no unit tracking


# -- Orchestrator.generate / maybe_replace infeasibility contract -------------

def test_generate_returns_none_when_infeasible():
    prof = Profiler(C.get("flux"))
    orch = Orchestrator(prof, num_chips=0)        # no units at all
    assert orch.generate([Request("flux", 1024)]) is None
    healthy = Orchestrator(prof, num_chips=128)
    assert healthy.generate([Request("flux", 1024)]) is not None


def test_maybe_replace_survives_infeasible_generate(monkeypatch):
    """Re-placement when no feasible plan exists must keep the old plan,
    not crash on ``None.type_histogram()``."""
    cfg = SimConfig(num_chips=128)
    prof = Profiler(C.get("sd3"))
    from repro.core import workloads
    trace = workloads.make_trace("sd3", "light", 30.0, prof, seed=0)
    sched = TridentScheduler(prof, cfg, trace)
    sim = Simulator("sd3", sched, trace, cfg)
    monkeypatch.setattr(sched.orch, "generate",
                        lambda *a, **kw: None)
    res = sim.run()            # bootstrap hits the OOM path gracefully
    assert res.oom

    # now a healthy bootstrap but infeasible *re*-placement
    sched2 = TridentScheduler(prof, cfg, trace)
    sim2 = Simulator("sd3", sched2, trace, cfg)
    plan = sched2.initial_placement()
    assert plan is not None
    from repro.core.runtime import RuntimeEngine
    sim2.engine = RuntimeEngine(prof, plan)
    sched2._recent = list(trace[:16])
    sched2._recent_ids = {r.rid for r in sched2._recent}
    monkeypatch.setattr(sim2.monitor, "pattern_change", lambda *a, **kw: True)
    monkeypatch.setattr(sched2.orch, "generate", lambda *a, **kw: None)
    assert sched2.maybe_replace(sim2, tau=100.0) is None
    assert sim2.engine.plan is plan               # old plan untouched


# -- idle-window wake-ups (stale-window fix) ----------------------------------

class _ProbeScheduler(TridentScheduler):
    """Records every re-placement check with the Monitor window state seen
    at that moment."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.checks = []

    def maybe_replace(self, sim, tau):
        self.checks.append((tau, len(sim.monitor._completions)))
        return super().maybe_replace(sim, tau)


from repro.core import workloads as workloads_mod


def _gap_trace(prof):
    """A burst at t<=5, a long idle gap, one straggler at t=200."""
    trace = workloads_mod.make_trace("sd3", "light", 5.0, prof, seed=0,
                                     rate=6.0)
    late = Request("sd3", 512, arrival=200.0)
    late.deadline = 200.0 + 2.5 * prof.pipeline_time(late)
    return trace + [late]


@pytest.mark.parametrize("idle_wakeups", [False, True])
def test_idle_window_wakeups_cover_the_gap(idle_wakeups):
    """The ROADMAP's known corner: the event clock used to schedule
    Monitor-window wake-ups only while requests were pending or in flight,
    so a pattern change during an idle gap went unseen until the next
    arrival — by which time the window had drained below MIN_SAMPLES.
    With ``SimConfig.idle_window_wakeups`` the window boundaries stay
    wake-up sources across the gap, so at least one re-placement check
    still sees the retained samples before they slide out."""
    prof = Profiler(C.get("sd3"))
    trace = _gap_trace(prof)
    cfg = SimConfig(num_chips=32, idle_window_wakeups=idle_wakeups)
    sched = _ProbeScheduler(prof, cfg, trace)
    sim = Simulator("sd3", sched, trace, cfg)
    res = sim.run()
    assert res.n_finished == res.n_requests
    # checks strictly inside the idle gap (after the burst drained, before
    # the straggler arrives)
    gap_checks = [(tau, n) for tau, n in sched.checks if 30.0 < tau < 200.0]
    if not idle_wakeups:
        # the pre-fix behavior this guards against: the clock sleeps
        # through the whole gap
        assert not gap_checks
    else:
        assert gap_checks, "window boundaries must wake the clock mid-gap"
        # and at least one such check still saw the burst's window samples
        # (the stale-window case: seen before the window drains)
        assert any(n > 0 for _, n in gap_checks)


def test_idle_window_wakeups_do_not_change_results():
    """The extra wake-ups are no-ops on quiet gaps: metrics must not move."""
    results = {}
    for flag in (False, True):
        cfg = SimConfig(num_chips=32, idle_window_wakeups=flag)
        results[flag] = run_sim("sd3", TridentScheduler, "light", 30.0,
                                sim_cfg=cfg)
    assert results[True].slo_attainment == results[False].slo_attainment
    assert results[True].mean_latency == results[False].mean_latency
    assert results[True].n_finished == results[False].n_finished


def test_adaptive_gap_and_idle_window_wakeups_compose():
    """Regression for the previously-untested flag interaction: with BOTH
    ``adaptive_idle_gap`` and ``idle_window_wakeups`` on, an idle gap
    spanning multiple Monitor windows must still be covered by
    window-boundary wake-ups — the adaptive heartbeat only widens the
    *pending* heartbeat, which is disarmed during a fully-idle gap, so it
    must neither suppress nor shift the boundary wake-up sequence."""
    prof = Profiler(C.get("sd3"))
    trace = _gap_trace(prof)
    checks = {}
    results = {}
    for adaptive in (False, True):
        cfg = SimConfig(num_chips=32, idle_window_wakeups=True,
                        adaptive_idle_gap=adaptive)
        sched = _ProbeScheduler(prof, cfg, trace)
        sched.t_win = 40.0   # gap (~30..200 s) spans ~4 Monitor windows
        sim = Simulator("sd3", sched, trace, cfg)
        results[adaptive] = sim.run()
        checks[adaptive] = sched.checks
    gap = {flag: [(tau, n) for tau, n in checks[flag] if 30.0 < tau < 200.0]
           for flag in (False, True)}
    for flag in (False, True):
        # the stale-window fix holds: the clock wakes inside the gap and at
        # least one check still sees the burst's retained window samples
        assert gap[flag], "window boundaries must wake the clock mid-gap"
        assert any(n > 0 for _, n in gap[flag])
        # boundary wake-ups exist only while samples are retained: nothing
        # fires deeper into the gap than one window past the last sample
        last_sample = max(tau for tau, n in checks[flag] if n > 0)
        assert all(tau <= last_sample + 40.0 + 0.25 for tau, _ in gap[flag])
    # the pinned interaction: the adaptive heartbeat is disarmed while idle,
    # so the wake-up sequence across the gap is window-driven and identical
    assert gap[True] == gap[False]
    # and the extra machinery never moves results
    assert (results[True].slo_attainment, results[True].n_finished) \
        == (results[False].slo_attainment, results[False].n_finished)
    assert results[True].mean_latency == results[False].mean_latency


# -- profile-guided max_idle_gap ----------------------------------------------

def test_adaptive_idle_gap_fewer_heartbeats_on_quiet_backlog():
    """When pending requests sit far from their deadlines (no aging flips),
    the adaptive heartbeat doubles its gap instead of waking every
    ``max_idle_gap`` — same results, fewer scheduler wake-ups."""
    results = {}
    for adaptive in (False, True):
        cfg = SimConfig(num_chips=16, adaptive_idle_gap=adaptive)
        results[adaptive] = run_sim("hunyuanvideo", TridentScheduler,
                                    "heavy", 60.0, sim_cfg=cfg,
                                    rate=1.0, slo_scale=60.0)
    fixed, adapt = results[False], results[True]
    assert adapt.sched_wakeups < fixed.sched_wakeups
    # heartbeats on a quiet backlog are no-ops: results must not move
    assert adapt.slo_attainment == fixed.slo_attainment
    assert adapt.n_finished == fixed.n_finished
    assert abs(adapt.mean_latency - fixed.mean_latency) < 1e-9
    assert abs(adapt.p95_latency - fixed.p95_latency) < 1e-9


def test_adaptive_idle_gap_resets_on_aging_flips():
    """With tight deadlines the backlog keeps crossing them — flips pin the
    gap near its base, so the wake-up saving shrinks (the gap never grows
    past a flip): the adaptive run stays within the fixed-gap count."""
    cfg_tight = SimConfig(num_chips=16, adaptive_idle_gap=True)
    tight = run_sim("hunyuanvideo", TridentScheduler, "heavy", 60.0,
                    sim_cfg=cfg_tight, rate=1.0, slo_scale=2.5)
    quiet = run_sim("hunyuanvideo", TridentScheduler, "heavy", 60.0,
                    sim_cfg=SimConfig(num_chips=16, adaptive_idle_gap=True),
                    rate=1.0, slo_scale=60.0)
    # a flip-heavy trace wakes at least as often as the quiet one
    assert tight.sched_wakeups >= quiet.sched_wakeups
