"""DET003 positive: module-level (global-state) RNG use.

`random.shuffle` / `random.random` mutate the interpreter-global Mersenne
state: any other import that touches the module RNG changes this call's
stream, so results depend on import order and unrelated code.
"""
import random


def jitter(xs):
    random.shuffle(xs)
    return [x + random.random() * 1e-6 for x in xs]
