"""DET002 negative: metrics-only wall-clock accumulation.

The sanctioned trident.py pattern — the clock is read to *report* solver
time, and the tainted value only ever flows into a metrics attribute
(`self.solver_time += ...`); it never reaches a comparison, loop bound, or
return, so scheduling decisions cannot depend on machine load.  Outside
the strict zone this is clean without any suppression.
"""
import time


class Scheduler:
    def __init__(self):
        self.solver_time = 0.0

    def tick(self, solve):
        t0 = time.perf_counter()
        plan = solve()
        self.solver_time += time.perf_counter() - t0   # metrics only
        return plan
