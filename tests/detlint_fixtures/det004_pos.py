"""DET004 positive: min/max selection over an unordered collection where
the key can tie.

`max` returns the *first* maximal element in iteration order; over a set
with a non-injective key, which element wins a tie follows
PYTHONHASHSEED.
"""


def pick_node(candidates: set, load: dict) -> int:
    return max(candidates, key=lambda n: load[n])
