"""DET004 negative: deterministic selection.

Either pin the walk order with `sorted()` (ties then resolve to the
smallest element, independent of hash seed) or use a total key — a tuple
that embeds the element itself breaks every tie deterministically.
"""


def pick_node(candidates: set, load: dict) -> int:
    return max(sorted(candidates), key=lambda n: load[n])


def pick_node_total_key(candidates: list, load: dict) -> int:
    # ordered iterable + element-embedding tie-break key
    return max(candidates, key=lambda n: (load[n], n))
