"""DET005 negative: sorted set walk before mutating shared state.

With the walk order pinned, the shared list's contents are a pure
function of the set's contents.
"""


def drain(idle_units: set, out: list) -> None:
    for u in sorted(idle_units):
        out.append(u)
