"""DET001 positive: float accumulation over unordered (set) iteration.

Verbatim reduction of the PR 4 bug class (fleet._repartition reload sum,
runtime.apply_placement downtime cost, monitor.mix_shift TV-distance):
a float `sum()` / `+=` fed by string-set iteration follows PYTHONHASHSEED
order, and float addition is not associative in the last ulp — so a
threshold comparison downstream can flip run-to-run.
"""


def reload_cost(missing: set, stage_load_time):
    # the fleet.py reload reduction: `missing` is a set of stage letters
    reload = 0.0
    for s in missing:
        reload += stage_load_time(s)
    return reload


def tv_distance(shares, basis):
    # the monitor.mix_shift reduction: set-union iteration feeding sum()
    keys = set(shares) | set(basis)
    return 0.5 * sum(abs(shares.get(k, 0.0) - basis.get(k, 0.0))
                     for k in keys)
