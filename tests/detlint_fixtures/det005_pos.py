"""DET005 positive: set iteration mutating shared scheduler state.

Appending to an outer list while walking a set bakes hash order into the
shared structure — every later consumer of `out` inherits the
PYTHONHASHSEED-dependent order even if it never touches a set itself.
"""


def drain(idle_units: set, out: list) -> None:
    for u in idle_units:
        out.append(u)
