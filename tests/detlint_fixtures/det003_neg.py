"""DET003 negative: seeded instance RNG.

A `random.Random(seed)` instance owns its state: the stream is a pure
function of the seed, untouched by other modules.
"""
import random


def jitter(xs, seed=0):
    rng = random.Random(seed)
    rng.shuffle(xs)
    return [x + rng.random() * 1e-6 for x in xs]
