"""DET001 negative: the sanctioned fixes for unordered float accumulation.

`sorted()` pins the walk order off PYTHONHASHSEED (the PR 4 fix); integer
counters are exact under any order; keyed-slot writes land each term in
its own slot, so order cannot change the result.
"""


def reload_cost(missing, stage_load_time):
    reload = 0.0
    for s in sorted(missing):          # the PR 4 fix: sorted set walk
        reload += stage_load_time(s)
    return reload


def tv_distance(shares, basis):
    keys = sorted(set(shares) | set(basis))
    return 0.5 * sum(abs(shares.get(k, 0.0) - basis.get(k, 0.0))
                     for k in keys)


def count_ready(pending):
    n = 0
    for _req in pending:               # int counter: exact, order-free
        n += 1
    return n


def per_stage_cost(missing, stage_load_time):
    cost = {}
    for s in missing:                  # keyed slot: each term its own key
        cost[s] = stage_load_time(s)
    return cost
