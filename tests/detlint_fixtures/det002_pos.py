"""DET002 positive: wall-clock read reaching control flow.

Verbatim reduction of the PR 5 bug: ilp.solve's anytime cap compared
`time.perf_counter()` against a deadline inside the DFS loop, so capped
solves stopped at a machine-load-dependent node and the same trace could
dispatch differently across re-runs (the fix translates the cap into a
node budget at a fixed calibration rate, NODES_PER_SECOND).
"""
import time


def solve(stack, expand, time_cap=0.2):
    t0 = time.perf_counter()
    best = None
    while stack:
        if time.perf_counter() - t0 > time_cap:   # load-dependent stop node
            break
        node = stack.pop()
        best = node if best is None else max(best, node)
        stack.extend(expand(node))
    return best
