"""Roofline machinery: trip-count-aware HLO parsing + dry-run smoke."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import hlo

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_scan_flops_match_unrolled():
    """cost_analysis counts while bodies once; our parser must not."""
    def body(c, _):
        return c @ c, None

    def scanned(x):
        return jax.lax.scan(body, x, None, length=8)[0]

    def unrolled(x):
        for _ in range(8):
            x = x @ x
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fl = {}
    for name, fn in (("scan", scanned), ("unroll", unrolled)):
        c = jax.jit(fn).lower(x).compile()
        fl[name] = hlo.module_costs(c.as_text(), 1).flops
    assert fl["scan"] == fl["unroll"] == 8 * 2 * 128 ** 3


def test_nested_scan_multipliers():
    def inner(c, _):
        return c @ c, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    def fn(x):
        return jax.lax.scan(outer, x, None, length=4)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(fn).lower(x).compile()
    mc = hlo.module_costs(c.as_text(), 1)
    assert mc.flops == 12 * 2 * 64 ** 3


def test_dot_flops_with_batch_dims():
    def fn(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    c = jax.jit(fn).lower(a, b).compile()
    mc = hlo.module_costs(c.as_text(), 1)
    assert mc.flops == 2 * 4 * 32 * 64 * 16


def test_collective_parsing_smoke():
    text = """
HloModule m

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %ag = f32[16,16]{1,0} all-gather(%ar), replica_groups={{0,1}}, dimensions={0}
}
"""
    mc = hlo.module_costs(text, 4)
    assert mc.collective_counts == {"all-reduce": 1, "all-gather": 1}
    # AR: 2*(3/4)*1024B; AG: (1/2)*1024B
    assert abs(mc.collective_wire_bytes - (2 * 0.75 * 1024 + 0.5 * 1024)) < 1


@pytest.mark.slow
def test_dryrun_single_combo_subprocess():
    """A small arch x decode compiles on a 64-device mesh in-process."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
        import jax
        import repro.configs as C
        from repro.launch import specs as specs_lib, dryrun
        spec = specs_lib.input_specs("internvl2-2b", "decode_32k")
        mesh = jax.make_mesh((8, 8), ("data", "model"))
        cfg = C.get("internvl2-2b")
        in_sh = dryrun.shardings_for(spec, cfg, mesh, False)
        with mesh:
            compiled = jax.jit(spec.fn, in_shardings=in_sh,
                               donate_argnums=(2,)).lower(*spec.args).compile()
        from repro.roofline import hlo
        mc = hlo.module_costs(compiled.as_text(), 64)
        assert mc.flops > 0 and mc.hbm_bytes > 0
        print("DRYRUN_OK")
    """
    env = dict(os.environ, PYTHONPATH=REPO_SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0 and "DRYRUN_OK" in out.stdout, out.stderr[-2000:]


def test_long500k_skip_reasons():
    from repro.launch import specs as specs_lib
    import repro.configs as C
    expected_skip = {"yi-34b", "yi-9b", "internvl2-2b", "deepseek-moe-16b",
                     "musicgen-medium"}
    for arch in C.ARCH_IDS:
        spec = specs_lib.input_specs(arch, "long_500k")
        if arch in expected_skip:
            assert spec.skipped, arch
        else:
            assert not spec.skipped, arch
