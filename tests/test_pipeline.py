"""Diffusion pipeline: stage split == end-to-end; serving engine wall-clock."""
import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import pipeline as pl
from repro.serving.engine import GenRequest, ServeEngine


@pytest.fixture(scope="module")
def sd3():
    cfg = C.get_smoke("sd3")
    params = pl.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_generate_shapes(sd3):
    cfg, params = sd3
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.encoder.vocab_size)
    out = pl.generate(cfg, params, toks, resolution=64, seconds=0.0,
                      key=jax.random.PRNGKey(2))
    assert out.shape == (2, 64, 64, 3)
    assert np.isfinite(np.asarray(out)).all()


def test_stagewise_equals_generate(sd3):
    """E→D→C run as separate dispatches == co-located ⟨EDC⟩ run (lossless
    stage-level serving — the paper's §9 'lossless' claim)."""
    cfg, params = sd3
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                              cfg.encoder.vocab_size)
    key = jax.random.PRNGKey(2)
    full = pl.generate(cfg, params, toks, 64, 0.0, key)
    grid = cfg.latent_grid(64, 0.0)
    cond = pl.encode(cfg, params, toks)
    lat = pl.diffuse(cfg, params, cond,
                     (1, cfg.latent_tokens(64, 0.0), cfg.dit.latent_dim), key)
    out = pl.decode(cfg, params, lat, grid)
    np.testing.assert_allclose(np.asarray(full), np.asarray(out),
                               atol=1e-5, rtol=1e-5)


def test_video_pipeline_shapes():
    cfg = C.get_smoke("cogvideox")
    params = pl.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0,
                              cfg.encoder.vocab_size)
    res, sec = 64, 1.0
    grid = cfg.latent_grid(res, sec)
    assert grid[0] > 1  # multiple latent frames
    out = pl.generate(cfg, params, toks, res, sec, jax.random.PRNGKey(2))
    assert out.shape == (grid[0], 64, 64, 3)


def test_proc_len_ordering():
    cfg = C.get("flux")
    for res in (512, 1024, 2048):
        assert (pl.stage_proc_len(cfg, "D", res, 0) >
                pl.stage_proc_len(cfg, "C", res, 0) >= 1)
        assert pl.stage_proc_len(cfg, "E", res, 0) <= 500  # Table 2


def test_serve_engine_batched():
    cfg = C.get_smoke("yi-9b")
    from repro.models import transformer as tf
    params = tf.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(GenRequest(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, size=rng.integers(4, 10)).astype(np.int32),
            max_new=4))
    done = eng.step() + eng.step()
    assert len(done) == 5
    for r in done:
        assert r.output.shape == (4,)
        assert r.output.dtype in (np.int32, np.int64)
