"""End-to-end behaviour: the paper's headline claims, at test scale."""
import pytest

from repro.core.baselines import BASELINES
from repro.core.simulator import SimConfig, run_sim
from repro.core.trident import TridentScheduler

DUR = 60.0


@pytest.fixture(scope="module")
def flux_results():
    out = {"trident": run_sim("flux", TridentScheduler, "medium", DUR)}
    for b in ("B1", "B3", "B6"):
        out[b] = run_sim("flux", BASELINES[b], "medium", DUR)
    return out


def test_colocated_baselines_oom_on_flux(flux_results):
    """Fig. 10: B1-B4 OOM on Flux (no MP fold); stage-level systems do not."""
    assert flux_results["B1"].oom
    assert flux_results["B3"].oom
    assert not flux_results["B6"].oom
    assert not flux_results["trident"].oom


def test_trident_beats_b6_on_flux(flux_results):
    t, b6 = flux_results["trident"], flux_results["B6"]
    assert t.slo_attainment > b6.slo_attainment
    assert t.n_finished == t.n_requests


def test_all_requests_complete(flux_results):
    t = flux_results["trident"]
    assert t.n_finished == t.n_requests
    assert t.n_request_oom == 0


def test_vr_distribution_prefers_low_comm(flux_results):
    """Fig. 12: most requests land on the lowest-communication VR type."""
    hist = flux_results["trident"].vr_histogram
    total = sum(hist.values())
    assert hist.get(0, 0) + hist.get(1, 0) > 0.8 * total


def test_sd3_colocated_baselines_run():
    """sd3 fits colocated (Table 2) — B1 must run, not OOM."""
    r = run_sim("sd3", BASELINES["B1"], "light", 30.0)
    assert not r.oom
    assert r.n_finished > 0


def test_trident_vs_b1_sd3_heavy():
    t = run_sim("sd3", TridentScheduler, "heavy", DUR)
    b1 = run_sim("sd3", BASELINES["B1"], "heavy", DUR)
    assert not b1.oom
    assert t.slo_attainment >= b1.slo_attainment


def test_ablation_stage_aware_helps_flux():
    full = run_sim("flux", TridentScheduler, "heavy", DUR)
    wo = run_sim("flux", TridentScheduler, "heavy", DUR, stage_aware=False)
    assert full.slo_attainment >= wo.slo_attainment


def test_proactive_push_no_worse():
    cfg_off = SimConfig(proactive_push=False)
    on = run_sim("hunyuanvideo", TridentScheduler, "medium", DUR)
    off = run_sim("hunyuanvideo", TridentScheduler, "medium", DUR,
                  sim_cfg=cfg_off)
    assert on.mean_latency <= off.mean_latency * 1.05


def test_deterministic_given_seed():
    a = run_sim("cogvideox", TridentScheduler, "medium", 30.0, seed=7)
    b = run_sim("cogvideox", TridentScheduler, "medium", 30.0, seed=7)
    assert a.slo_attainment == b.slo_attainment
    assert a.mean_latency == b.mean_latency


@pytest.mark.slow
def test_dynamic_batching_improves_light_flood():
    """App. E.1: batching same-class lightweight requests improves p95
    under a light-request flood; and every batched request still finishes."""
    on = run_sim("sd3", TridentScheduler, "dynamic", 120.0, rate=45.0)
    off = run_sim("sd3", TridentScheduler, "dynamic", 120.0, rate=45.0,
                  enable_batching=False)
    assert on.n_finished == on.n_requests
    assert on.p95_latency <= off.p95_latency
    assert on.slo_attainment >= off.slo_attainment


@pytest.mark.slow
def test_cross_node_sp_reduces_heavy_latency():
    """Beyond-paper pod-wide SP: heavy flux requests finish faster."""
    base = run_sim("flux", TridentScheduler, "heavy", 120.0)
    wide = run_sim("flux", TridentScheduler, "heavy", 120.0,
                   cross_node_sp=True)
    assert wide.mean_latency < base.mean_latency
    assert wide.n_finished == wide.n_requests
