"""Render markdown tables for EXPERIMENTS.md from results/*.jsonl."""
import json, sys

def dryrun_table(path, mesh_label):
    rows = []
    for l in open(path):
        r = json.loads(l)
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped: {r['reason'][:58]}… | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | | | {r.get('error','')[:60]} | | |")
            continue
        dom = r["bottleneck"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} | "
            f"{r['t_memory_s']*1e3:.1f} | {r['t_collective_s']*1e3:.2f} | "
            f"**{dom}** | {r['useful_ratio']:.3f} | "
            f"{r.get('peak_mem_per_device',0)/2**30:.1f} |")
    hdr = (f"\n### {mesh_label}\n\n"
           "| arch | shape | compute (ms) | memory (ms) | collective (ms) | bottleneck | MODEL/HLO flops | peak mem (GiB/chip) |\n"
           "|---|---|---|---|---|---|---|---|\n")
    return hdr + "\n".join(rows) + "\n"

def e2e_table(path):
    recs = [json.loads(l) for l in open(path)]
    by = {}
    for r in recs:
        by.setdefault((r["pipeline"], r["workload"]), {})[r["scheduler"]] = r
    out = ["| pipeline | workload | metric | Trident | B1 | B2 | B3 | B4 | B5 | B6 |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    order = ["trident","B1","B2","B3","B4","B5","B6"]
    for (pid, wl), d in sorted(by.items()):
        def fmt(s, key):
            r = d.get(s)
            if r is None: return "·"
            if r["oom"]: return "OOM"
            v = r[key]
            return f"{v*100:.1f}" if key == "slo" else f"{v:.1f}"
        for key, lab in (("slo","SLO %"),("mean","mean s"),("p95","p95 s")):
            out.append(f"| {pid} | {wl} | {lab} | " + " | ".join(fmt(s,key) for s in order) + " |")
    return "\n".join(out) + "\n"

if __name__ == "__main__":
    which = sys.argv[1]
    if which == "dryrun":
        print(dryrun_table(sys.argv[2], sys.argv[3]))
    elif which == "e2e":
        print(e2e_table(sys.argv[2]))
