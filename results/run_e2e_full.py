import sys, json, time
sys.path.insert(0, "src")
from repro.core.baselines import BASELINES
from repro.core.simulator import run_sim
from repro.core.trident import TridentScheduler

DUR = 300.0
out = open("results/e2e_full.jsonl", "w")
scheds = {"trident": TridentScheduler, **BASELINES}
for pid in ("sd3", "flux", "cogvideox", "hunyuanvideo"):
    for wl in ("light", "medium", "heavy", "dynamic", "proprietary"):
        for name, cls in scheds.items():
            t0 = time.perf_counter()
            r = run_sim(pid, cls, wl, DUR)
            rec = dict(pipeline=pid, workload=wl, scheduler=name, oom=r.oom,
                       slo=round(r.slo_attainment, 4),
                       mean=round(r.mean_latency, 3) if not r.oom else None,
                       p95=round(r.p95_latency, 3) if not r.oom else None,
                       n=r.n_requests, fin=r.n_finished,
                       wall=round(time.perf_counter() - t0, 1))
            out.write(json.dumps(rec) + "\n"); out.flush()
            print(rec, flush=True)
print("E2E_FULL_DONE")
